"""Generation-first serving: token-level equivalence of the
continuous-batching DecodeScheduler against the serial reference loop,
join/leave isolation, KV-overflow validation, typed router errors.

The load-bearing property: every token sequence produced through the
Router — cold (first token sampled inside the loading pipeline) or
warm, at any concurrency — is *bit-identical* to
``reference_generate``'s serial B=1 prefill + decode_step loop.
"""
import dataclasses
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer
from repro.models.api import get_config
from repro.serving import (BatchedLMServer, CacheOverflowError,
                           DecodeScheduler, GenerateSpec, InstancePool,
                           Request, Router, UnknownModelError,
                           reference_generate)
from repro.store.store import WeightStore, deploy_model

CACHE_LEN = 64
PROMPT_LEN = 8

# dense / MoE / hybrid smoke archs (f32 so bit-identity is meaningful)
GEN_ARCHS = ["smollm-360m", "mixtral-8x7b", "recurrentgemma-2b"]


def _f32_cfg(arch):
    return dataclasses.replace(get_config(arch, smoke=True),
                               compute_dtype=jnp.float32)


def _prompt(cfg, seed):
    r = np.random.default_rng(seed)
    return r.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32)


@pytest.fixture(scope="module")
def dense():
    """Model + params only (no store): scheduler-level tests."""
    cfg = _f32_cfg("smollm-360m")
    m = transformer.build(cfg)
    return cfg, m, m.init(jax.random.key(0))


# ---------------------------------------------------------------------------
# Router-level equivalence: cold + warm, concurrency 1 and N, per family
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", GEN_ARCHS)
def test_router_generation_bit_identical(arch, tmp_path):
    cfg = _f32_cfg(arch)
    m = transformer.build(cfg)
    store = WeightStore(str(tmp_path / "store"))
    deploy_model(store, m, arch, jax.random.key(0))
    example = {"tokens": jnp.asarray(_prompt(cfg, 99)[None])}
    pool = InstancePool(arch, lambda: (m, example), store,
                        strategy="cicada", max_instances=1,
                        gen_slots=4, gen_cache_len=CACHE_LEN)
    n_new = 6
    prompts = {i: _prompt(cfg, i) for i in range(6)}

    with Router({arch: pool}, workers=4) as router:
        # cold: first token produced by the loading pipeline itself
        r0 = router.submit(Request(req_id=0, model=arch,
                                   gen=GenerateSpec(prompt=prompts[0],
                                                    n_new=n_new))).result()
        assert r0.cold and r0.load_s > 0
        assert r0.ttft_s < r0.load_s          # TTFT inside the load
        # warm, concurrency 1
        r1 = router.submit(Request(req_id=1, model=arch,
                                   gen=GenerateSpec(prompt=prompts[1],
                                                    n_new=n_new))).result()
        assert not r1.cold and r1.ttft_s > 0
        # warm, concurrency 4: requests join one instance's batch
        futs = [router.submit(Request(req_id=i, model=arch,
                                      gen=GenerateSpec(prompt=prompts[i],
                                                       n_new=n_new)))
                for i in range(2, 6)]
        rest = [f.result(timeout=600) for f in futs]

    params = pool._instances[0].params
    for i, resp in enumerate([r0, r1] + rest):
        ref = reference_generate(m, params, prompts[i], n_new=n_new,
                                 cache_len=CACHE_LEN)
        assert list(resp.tokens) == ref, \
            f"{arch} req {i} (cold={resp.cold}) diverged from the " \
            f"serial reference"
        assert len(resp.tpot_s) == n_new - 1
        assert resp.ttft_s >= 0 and all(dt >= 0 for dt in resp.tpot_s)


# ---------------------------------------------------------------------------
# Scheduler-level: join/leave isolation, EOS, sampling determinism
# ---------------------------------------------------------------------------

def test_join_mid_batch_does_not_perturb_other_slots(dense):
    """A long generation in flight; a second request joins mid-batch:
    both must still match their solo serial references."""
    cfg, m, params = dense
    sched = DecodeScheduler(m, params, n_slots=4, cache_len=CACHE_LEN)
    pa, pb = _prompt(cfg, 1), _prompt(cfg, 2)
    out = {}

    def run_a():
        out["a"] = sched.generate(GenerateSpec(prompt=pa, n_new=24)).tokens

    ta = threading.Thread(target=run_a)
    ta.start()
    deadline = time.monotonic() + 120
    while sched.stats()["steps"] < 2:      # A's decode is running
        assert time.monotonic() < deadline, "A never started stepping"
        time.sleep(0.002)
    out["b"] = sched.generate(GenerateSpec(prompt=pb, n_new=6)).tokens
    ta.join(timeout=120)
    assert not ta.is_alive()

    assert out["a"] == reference_generate(m, params, pa, n_new=24,
                                          cache_len=CACHE_LEN)
    assert out["b"] == reference_generate(m, params, pb, n_new=6,
                                          cache_len=CACHE_LEN)
    assert sched.stats()["max_occupancy"] >= 2    # they truly overlapped
    assert sched.stats()["active"] == 0           # both left their slots


def test_leave_frees_slot_for_next_joiner(dense):
    """More requests than slots: later requests wait for a slot, then
    join — every sequence still matches its reference."""
    cfg, m, params = dense
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN)
    prompts = {i: _prompt(cfg, 10 + i) for i in range(4)}
    results = {}

    def run(i):
        results[i] = sched.generate(
            GenerateSpec(prompt=prompts[i], n_new=5)).tokens

    threads = [threading.Thread(target=run, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive()
    for i in range(4):
        assert results[i] == reference_generate(
            m, params, prompts[i], n_new=5, cache_len=CACHE_LEN)
    assert sched.stats()["max_occupancy"] <= 2


def test_eos_leaves_early(dense):
    cfg, m, params = dense
    p = _prompt(cfg, 3)
    ref = reference_generate(m, params, p, n_new=8, cache_len=CACHE_LEN)
    eos = ref[2]                               # stop at the third token
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN)
    got = sched.generate(GenerateSpec(prompt=p, n_new=8,
                                      eos_id=int(eos))).tokens
    assert got == ref[:3]
    assert sched.stats()["active"] == 0


def test_sampled_generation_deterministic_and_matches_reference(dense):
    cfg, m, params = dense
    p = _prompt(cfg, 4)
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN)
    spec = GenerateSpec(prompt=p, n_new=6, temperature=0.8, seed=7)
    a = sched.generate(spec).tokens
    b = sched.generate(spec).tokens
    assert a == b                              # same seed -> same tokens
    assert a == reference_generate(m, params, p, n_new=6,
                                   cache_len=CACHE_LEN, temperature=0.8,
                                   seed=7)


# ---------------------------------------------------------------------------
# KV-cache overflow validation + honored max_batch (old silent bugs)
# ---------------------------------------------------------------------------

def test_overflow_raises_instead_of_silent_wrap(dense):
    cfg, m, params = dense
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=16)
    with pytest.raises(CacheOverflowError):
        sched.generate(GenerateSpec(prompt=_prompt(cfg, 5), n_new=16))
    # validation happens before any slot is touched
    assert sched.stats()["active"] == 0 and sched.stats()["steps"] == 0


def test_max_len_clamps_n_new(dense):
    cfg, m, params = dense
    p = _prompt(cfg, 6)                        # 8-token prompt
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN)
    got = sched.generate(GenerateSpec(prompt=p, n_new=100,
                                      max_len=PROMPT_LEN + 4)).tokens
    assert len(got) == 4
    assert got == reference_generate(m, params, p, n_new=4,
                                     cache_len=CACHE_LEN)
    with pytest.raises(CacheOverflowError):    # no room to generate at all
        sched.generate(GenerateSpec(prompt=p, n_new=4,
                                    max_len=PROMPT_LEN))


def test_batched_server_honors_max_batch(dense):
    cfg, m, params = dense
    srv = BatchedLMServer(m, params, max_batch=2, cache_len=CACHE_LEN)
    toks = jnp.asarray(np.stack([_prompt(cfg, i) for i in range(3)]))
    with pytest.raises(ValueError, match="max_batch"):
        srv.generate(toks, n_new=4)            # was a dead knob before
    out = srv.generate(toks[:2], n_new=4)
    assert out.shape == (2, 4)
    with pytest.raises(CacheOverflowError):
        srv.generate(toks[:1], n_new=CACHE_LEN)


# ---------------------------------------------------------------------------
# pool fairness: shared generation holds must not starve one-shot work
# ---------------------------------------------------------------------------

def test_oneshot_not_starved_by_generation_holds():
    """While an exclusive acquire() waits, no new generation joins are
    granted — resident generations drain and the one-shot wins, instead
    of a continuous joiner stream keeping the instance busy forever."""
    from test_router_pool import FakeInstance
    insts = []

    def factory():
        inst = FakeInstance(load_s=0.01)
        inst.gen_slots = 4
        insts.append(inst)
        return inst

    pool = InstancePool("m", builder=None, instance_factory=factory,
                        max_instances=1)
    inst = pool.acquire()
    inst.invoke({})                          # make it live
    pool.release(inst, logical_now=0.0, cold=True)

    gi, joinable = pool.acquire_gen()
    assert joinable and gi is inst

    # Router-style requeue gap: an exclusive acquire that TIMED OUT (no
    # longer parked in wait) keeps new joins paused via the sticky
    # starvation window until it retries and wins.
    with pytest.raises(TimeoutError):
        pool.acquire(timeout=0.02)
    with pytest.raises(TimeoutError):
        pool.acquire_gen(timeout=0.02)       # join refused in the gap
    got = {}

    def exclusive():
        got["inst"] = pool.acquire(timeout=10.0)

    t = threading.Thread(target=exclusive)
    t.start()
    deadline = time.monotonic() + 10
    while pool._excl_waiters == 0:           # exclusive is now parked
        assert time.monotonic() < deadline, "acquire never blocked"
        time.sleep(0.002)
    with pytest.raises(TimeoutError):        # new joins paused meanwhile
        pool.acquire_gen(timeout=0.05)
    pool.release_gen(gi, logical_now=0.0, cold=False)
    t.join(timeout=10)
    assert not t.is_alive() and got["inst"] is inst
    pool.release(got["inst"], logical_now=0.0)
    gi2, joinable2 = pool.acquire_gen(timeout=1.0)   # joins resume after
    assert joinable2
    pool.release_gen(gi2, logical_now=0.0)


# ---------------------------------------------------------------------------
# typed router errors (no jax needed)
# ---------------------------------------------------------------------------

def test_gen_join_resumes_after_starvation_window_expires():
    """An exclusive acquire that timed out and never retries must not
    block generation joins forever: a parked joiner wakes at the sticky
    window's expiry even though nothing notifies the CV."""
    from test_router_pool import FakeInstance
    insts = []

    def factory():
        inst = FakeInstance(load_s=0.01)
        inst.gen_slots = 4
        insts.append(inst)
        return inst

    pool = InstancePool("m", builder=None, instance_factory=factory,
                        max_instances=1)
    pool.EXCL_STARVATION_GRACE_S = 0.3
    inst = pool.acquire()
    inst.invoke({})
    pool.release(inst, logical_now=0.0, cold=True)
    gi, _ = pool.acquire_gen()
    with pytest.raises(TimeoutError):        # arms the sticky window
        pool.acquire(timeout=0.02)
    pool.release_gen(gi, logical_now=0.0, cold=False)  # instance idle+live
    t0 = time.monotonic()
    gi2, joinable = pool.acquire_gen(timeout=30.0)
    assert joinable and gi2 is inst
    assert time.monotonic() - t0 < 5.0       # woke at ~0.3 s, not 30 s
    pool.release_gen(gi2, logical_now=0.0)


def test_cancelled_future_does_not_kill_worker():
    """A request cancelled while queued is dropped at dispatch time —
    the worker must survive (set_result on a cancelled future raises)
    and keep serving later submissions."""
    from test_router_pool import fake_pool, _req
    pool = fake_pool(max_instances=1, load_s=0.2)
    with Router({"m": pool}, workers=1) as router:
        blocker = router.submit(_req(0))
        deadline = time.monotonic() + 5
        while pool.stats().busy < 1:         # worker inside the load
            assert time.monotonic() < deadline
            time.sleep(0.005)
        victim = router.submit(_req(1))
        assert victim.cancel()
        after = router.submit(_req(2))       # must still be served
        blocker.result(timeout=10)
        assert after.result(timeout=10).req_id == 2
        assert victim.cancelled()


def test_unknown_model_typed_error_on_submitting_thread():
    from test_router_pool import fake_pool
    with Router({"m": fake_pool()}, workers=1) as router:
        with pytest.raises(UnknownModelError, match="nope"):
            router.submit(Request(req_id=0, model="nope", batch={}))
        assert isinstance(UnknownModelError("x"), KeyError)  # compat
        # the failed submit left no queued work behind
        assert router.stats.submitted == 0
    # generation requests fail the same way, before any worker sees them
    with Router({"m": fake_pool()}, workers=1) as router:
        with pytest.raises(UnknownModelError):
            router.submit(Request(req_id=1, model="nope",
                                  gen=GenerateSpec(prompt=[1, 2, 3])))


# ---------------------------------------------------------------------------
# kernel-registry wiring: serving exercises the Pallas kernel bodies
# ---------------------------------------------------------------------------

def test_scheduler_runs_interpret_kernels_bit_identical(dense, monkeypatch):
    """The DecodeScheduler's jitted prefill/step dispatch the *Pallas
    kernel bodies* — the registry records the dispatches — and the
    token stream stays bit-identical to the serial reference traced
    under the same mode.  Default (and any non-TPU run): interpret
    mode.  CI's workflow_dispatch tpu-pallas leg exports
    REPRO_PALLAS=pallas on a TPU runner and the same assertions hold
    against the real Mosaic lowerings."""
    import os

    from repro.kernels import ops

    cfg, m, params = dense
    mode = os.environ.get("REPRO_PALLAS")
    if mode != "pallas":
        mode = "interpret"
    monkeypatch.setenv("REPRO_PALLAS", mode)
    before = ops.registry.dispatch_snapshot()
    sched = DecodeScheduler(m, params, n_slots=2, cache_len=CACHE_LEN)
    assert sched.kernel_modes["flash_attention"] == mode
    assert sched.kernel_modes["decode_attention"] == mode
    spec = GenerateSpec(prompt=_prompt(cfg, 5), n_new=4)
    got = sched.generate(spec).tokens
    want = reference_generate(m, params, spec.prompt, n_new=4,
                              cache_len=CACHE_LEN)
    assert got == want
    after = ops.registry.dispatch_snapshot()
    for kern in ("flash_attention", "decode_attention"):
        assert after.get((kern, mode), 0) > \
            before.get((kern, mode), 0), kern


def test_registry_auto_probes_and_forces(monkeypatch):
    """auto resolves through the cached capability probe (ref on CPU);
    set_mode overrides the env var; bogus modes fail loudly."""
    from repro.kernels import ops

    monkeypatch.delenv("REPRO_PALLAS", raising=False)
    desc = ops.registry.describe()
    assert set(desc) == {"flash_attention", "decode_attention",
                         "decode_attention_paged", "ssd_scan",
                         "rglru_scan", "weight_transform",
                         "quant_matmul"}
    if jax.default_backend() != "tpu":
        assert all(not d["pallas_supported"] for d in desc.values())
        assert all(d["mode"] == "ref" for d in desc.values())
    monkeypatch.setenv("REPRO_PALLAS", "xla")       # legacy alias
    assert ops.registry.mode("flash_attention") == "ref"
    ops.set_mode("interpret")                       # flag beats env
    try:
        assert ops.registry.mode("flash_attention") == "interpret"
        assert ops.registry.fingerprint()[0] == "interpret"
        assert ops.registry.modes()["flash_attention"] == "interpret"
    finally:
        ops.set_mode(None)
    with pytest.raises(ValueError, match="must be one of"):
        ops.set_mode("vulkan")
