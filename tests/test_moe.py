"""MoE routing / dispatch properties."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from repro.models import moe
from repro.models.api import get_config


def _cfg(**kw):
    base = get_config("mixtral-8x7b", smoke=True)
    return dataclasses.replace(base, compute_dtype=jnp.float32, **kw)


def _x(cfg, B=2, S=16, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.standard_normal((B, S, cfg.d_model)), jnp.float32)


def test_no_drop_matches_dense_reference():
    """With capacity >= S the gather dispatch equals compute-all-experts."""
    cfg = _cfg(capacity_factor=float(4 / 2 * 2))   # C = S
    p = moe.moe_params(cfg, jax.random.key(0))
    x = _x(cfg)
    y, aux = moe.moe_block(cfg, p, x)
    y_ref = moe.moe_block_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


def test_routing_weights_normalized():
    cfg = _cfg()
    p = moe.moe_params(cfg, jax.random.key(1))
    x = _x(cfg)
    w_te, probs, mask = moe.route(cfg, p["router"], x)
    w = np.asarray(w_te)
    # each token's weights sum to 1 over its top-k experts
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    # exactly top_k experts per token
    np.testing.assert_array_equal((w > 0).sum(-1),
                                  np.full(w.shape[:2], cfg.top_k))
    # probs are a distribution
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, atol=1e-5)


@given(seed=st.integers(0, 2 ** 10))
def test_capacity_bounds_tokens_per_expert(seed):
    cfg = _cfg(capacity_factor=1.0)
    p = moe.moe_params(cfg, jax.random.key(seed))
    x = _x(cfg, seed=seed)
    B, S, _ = x.shape
    C = moe.capacity(cfg, S)
    w_te, _, _ = moe.route(cfg, p["router"], x)
    w_et = jnp.swapaxes(w_te, 1, 2)
    g, idx = jax.lax.top_k(w_et, C)
    # at most C tokens per (row, expert) contribute
    assert g.shape[-1] == C <= S


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss == 1 (the minimum)."""
    E, B, S, k = 8, 4, 64, 2
    probs = jnp.full((B, S, E), 1.0 / E)
    mask = jnp.zeros((B, S, E)).at[..., :k].set(1.0)  # k experts per token
    # uniform dispatch: rotate assignment so every expert gets equal load
    mask = jnp.stack([jnp.roll(mask[b], b, axis=-1) for b in range(B)])
    loss = moe.load_balance_loss(probs, mask, E)
    np.testing.assert_allclose(float(loss), float(k), rtol=1e-5)


def test_dense_residual_arctic():
    cfg = dataclasses.replace(get_config("arctic-480b", smoke=True),
                              compute_dtype=jnp.float32)
    assert cfg.dense_residual
    p = moe.moe_params(cfg, jax.random.key(0))
    assert "dense" in p
    x = _x(cfg)
    y, _ = moe.moe_block(cfg, p, x)
    # residual actually contributes: zeroing dense params changes output
    p2 = dict(p)
    p2["dense"] = jax.tree.map(jnp.zeros_like, p["dense"])
    y2, _ = moe.moe_block(cfg, p2, x)
    assert np.abs(np.asarray(y) - np.asarray(y2)).max() > 1e-4


def test_decode_single_token_routing():
    """S=1 (decode): every token is served, no drops possible."""
    cfg = _cfg(capacity_factor=1.0)
    p = moe.moe_params(cfg, jax.random.key(2))
    x = _x(cfg, B=4, S=1)
    y, _ = moe.moe_block(cfg, p, x)
    y_ref = moe.moe_block_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
