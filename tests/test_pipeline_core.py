"""PipelineTrace math (utilization, waits, memory) + Priority-Aware
Scheduler (Algorithm 1) unit tests.

Property-based variants live in test_pipeline_props.py (hypothesis).
"""
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import PipelineTrace
from repro.core.scheduler import HIGH, NORMAL, PriorityAwareScheduler


# ---------------------------------------------------------------------------
# trace math
# ---------------------------------------------------------------------------

def _trace(events, t0=0.0, t1=None):
    tr = PipelineTrace()
    tr.t0 = t0
    for stage, layer, a, b in events:
        tr.add_event(stage, layer, a, b)
    tr.t_end = t1 if t1 is not None else max(e[3] for e in events)
    return tr


def test_utilization_no_overlap():
    tr = _trace([("L", "u0", 0.0, 1.0), ("A", "u0", 1.0, 2.0),
                 ("E", "u0", 2.0, 3.0)])
    assert tr.total_time() == 3.0
    assert tr.busy_time() == 3.0
    assert tr.utilization() == 1.0


def test_utilization_counts_overlap_once():
    tr = _trace([("L", "u0", 0.0, 2.0), ("R", "u1", 0.0, 2.0),
                 ("A", "u0", 1.0, 3.0)])
    assert tr.busy_time() == 3.0          # union [0,3], overlaps merged
    assert tr.utilization() == 1.0


def test_idle_gap_reduces_utilization():
    tr = _trace([("L", "u0", 0.0, 1.0), ("E", "u0", 3.0, 4.0)])
    assert tr.busy_time() == 2.0
    assert tr.total_time() == 4.0
    assert tr.utilization() == 0.5


def test_wait_times_per_paper_definition():
    """wait(A_i) = start(A_i) - end(L_i); wait(E_i) = start(E_i) - end(A_i)."""
    tr = _trace([("L", "u0", 0.0, 1.0), ("A", "u0", 1.5, 2.0),
                 ("E", "u0", 3.0, 3.5)])
    w = tr.wait_by_stage()
    assert w["A"] == pytest.approx(0.5)
    assert w["E"] == pytest.approx(1.0)


def test_memory_accounting():
    tr = PipelineTrace()
    tr.t0 = 0.0
    tr.record_memory("u0", 1000, 0.0, 2.0)
    tr.record_memory("u1", 500, 1.0, 3.0)   # overlaps u0 -> peak 1500
    tr.record_memory("u2", 200, 4.0, 5.0)
    tr.t_end = 5.0
    assert tr.memory_overhead_bytes() == 1500
    assert tr.memory_total_bytes() == 1700
    assert tr.memory_usage_time() == pytest.approx(2.0 + 2.0 + 1.0)


def test_gantt_rows_ordering():
    tr = _trace([("E", "u0", 2.0, 3.0), ("L", "u0", 0.0, 1.0)])
    rows = tr.gantt_rows()
    assert rows[0]["stage"] == "L" and rows[0]["start"] == 0.0
    assert rows[1]["row"] == "Compute"
    assert "Layer" in tr.render_gantt(40)


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def test_scheduler_normal_before_expected_completion():
    s = PriorityAwareScheduler(bw_bytes_per_s=1e9)
    s.register("w0", nbytes=10 ** 9)       # expected ~1s
    s.on_issue("w0")
    assert s.adjust_priority("w0") == NORMAL
    assert s.suspend_count == 0


def test_scheduler_suspends_others_when_late():
    s = PriorityAwareScheduler(bw_bytes_per_s=1e12, a_overhead_s=0.0)
    st0 = s.register("w0", nbytes=10)      # expected completion ~instant
    st1 = s.register("w1", nbytes=10)
    st2 = s.register("w2", nbytes=10)
    for u in ("w0", "w1", "w2"):
        s.on_issue(u)
    time.sleep(0.01)                       # now past expected completion
    assert s.adjust_priority("w0") == HIGH
    assert not st1.gate.is_set()           # suspended
    assert not st2.gate.is_set()
    assert st0.gate.is_set()               # critical stream still running
    # completion resumes the others
    s.on_complete("w0")
    assert st1.gate.is_set() and st2.gate.is_set()


def test_scheduler_completed_stream_is_normal():
    s = PriorityAwareScheduler(bw_bytes_per_s=1e12)
    s.register("w0", nbytes=10)
    s.on_issue("w0")
    s.on_complete("w0")
    time.sleep(0.005)
    assert s.adjust_priority("w0") == NORMAL
    assert s.suspend_count == 0


def test_scheduler_bandwidth_ema_updates():
    s = PriorityAwareScheduler(bw_bytes_per_s=1e9)
    s.register("w0", nbytes=50_000_000)
    s.on_issue("w0")
    time.sleep(0.05)                       # ~1e9 observed
    s.on_complete("w0")
    bw = s.stats()["bw_estimate"]
    assert bw != 1e9                       # EMA moved toward observation


def test_scheduler_disabled_never_suspends():
    s = PriorityAwareScheduler(bw_bytes_per_s=1e12, enabled=False)
    s.register("w0", nbytes=10)
    s.register("w1", nbytes=10)
    s.on_issue("w0")
    time.sleep(0.01)
    assert s.adjust_priority("w0") == NORMAL
    assert s.suspend_count == 0
