"""Node-local shared WeightCache: budget-bounded eviction order,
refcount pinning, single-flight under concurrent scale-out (exactly
one store read per unit), and cache-hit cold starts with ~zero
retrieval time."""
import threading
import time

import numpy as np
import pytest

from repro.store.cache import HIT, LOAD, WeightCache


# ---------------------------------------------------------------------------
# cache unit behaviour (no jax, no store)
# ---------------------------------------------------------------------------

def _put(c, model, unit, nbytes, value=None):
    status, _ = c.begin(model, unit)
    assert status == LOAD
    c.complete(model, unit, value if value is not None else {unit: nbytes},
               nbytes)
    c.release(model, unit)          # drop the loader's pin


def test_budget_bounded_lru_eviction_order():
    c = WeightCache(budget_bytes=250)
    _put(c, "m", "u0", 100)
    _put(c, "m", "u1", 100)
    assert ("m", "u0") in c and ("m", "u1") in c
    # refresh u0's recency: u1 is now the LRU victim
    s, _ = c.begin("m", "u0")
    assert s == HIT
    c.release("m", "u0")
    _put(c, "m", "u2", 100)        # 300 > 250 -> one eviction
    st = c.stats()
    assert st.bytes_cached <= 250
    assert st.evictions == 1
    assert ("m", "u1") not in c            # LRU evicted first
    assert ("m", "u0") in c and ("m", "u2") in c


def test_refcount_pin_survives_budget_pressure():
    c = WeightCache(budget_bytes=100)
    status, _ = c.begin("m", "pinned")
    assert status == LOAD
    c.complete("m", "pinned", {"w": 1}, 80)   # loader's pin still held
    _put(c, "m", "other", 80)                 # over budget
    assert ("m", "pinned") in c               # in-use unit survives pressure
    assert ("m", "other") not in c            # the unpinned one paid
    c.release("m", "pinned")
    _put(c, "m", "next", 80)                  # pin dropped -> now evictable
    assert ("m", "pinned") not in c


def test_inflight_model_units_evicted_last():
    """Priority-aware order: units of a model with a registered
    in-flight load are spared until idle models' units are gone."""
    c = WeightCache(budget_bytes=150)
    _put(c, "busy", "u0", 100)
    c.register_load("busy")
    _put(c, "idle", "u0", 100)     # over budget: "idle" evicted, not "busy"
    assert ("busy", "u0") in c
    assert ("idle", "u0") not in c
    c.unregister_load("busy")      # protection lapses -> budget re-enforced
    _put(c, "idle2", "u0", 100)
    assert ("busy", "u0") not in c


def test_single_flight_one_leader_many_waiters():
    c = WeightCache(None)
    outcomes = []
    release = threading.Event()

    def leader():
        status, _ = c.begin("m", "u")
        assert status == LOAD
        release.wait(5.0)
        c.complete("m", "u", {"w": 42}, 10)

    def follower():
        status, leaves = c.begin("m", "u")
        outcomes.append((status, leaves))

    tl = threading.Thread(target=leader)
    tl.start()
    time.sleep(0.02)               # leader holds the LOAD slot
    ts = [threading.Thread(target=follower) for _ in range(4)]
    for t in ts:
        t.start()
    time.sleep(0.02)
    assert not outcomes            # followers block on the leader
    release.set()
    tl.join(5.0)
    for t in ts:
        t.join(5.0)
    assert outcomes == [(HIT, {"w": 42})] * 4
    st = c.stats()
    assert st.misses == 1 and st.hits == 4 and st.waits == 4


def test_aborted_leader_promotes_a_waiter():
    c = WeightCache(None)
    got = {}

    def follower():
        status, _ = c.begin("m", "u")
        got["status"] = status

    status, _ = c.begin("m", "u")
    assert status == LOAD
    t = threading.Thread(target=follower)
    t.start()
    time.sleep(0.02)
    c.abort("m", "u")              # leader's read failed
    t.join(5.0)
    assert got["status"] == LOAD   # waiter retries as the new leader
    c.complete("m", "u", {"w": 1}, 4)
    assert ("m", "u") in c


def test_stats_snapshot_and_clear():
    c = WeightCache(budget_bytes=1000)
    _put(c, "m", "u0", 100)
    s, _ = c.begin("m", "u0")
    assert s == HIT
    st = c.stats()
    assert st.entries == 1 and st.pinned == 1
    assert st.hit_rate == pytest.approx(0.5)
    c.clear()
    assert ("m", "u0") in c        # pinned entries survive clear()
    c.release("m", "u0")
    c.clear()
    assert ("m", "u0") not in c
    assert c.stats().bytes_cached == 0


# ---------------------------------------------------------------------------
# integration: cold starts through the engine / pool share one cache
# ---------------------------------------------------------------------------

class CountingStore:
    """WeightStore wrapper counting physical read_unit calls."""

    def __new__(cls, *a, **kw):
        from repro.store.store import WeightStore

        class _Counting(WeightStore):
            def __init__(self, *args, **kwargs):
                super().__init__(*args, **kwargs)
                self.reads = 0
                self._read_lock = threading.Lock()

            def read_unit(self, *args, **kwargs):
                with self._read_lock:
                    self.reads += 1
                return super().read_unit(*args, **kwargs)

        return _Counting(*a, **kw)


@pytest.fixture(scope="module")
def deployed(tmp_path_factory):
    import jax
    import jax.numpy as jnp
    from repro.models import transformer
    from repro.models.api import get_config
    from repro.store.store import BandwidthModel, deploy_model

    d = tmp_path_factory.mktemp("store")
    cfg = get_config("smollm-360m", smoke=True)
    m = transformer.build(cfg)
    store = CountingStore(str(d), BandwidthModel(bandwidth_mbps=150,
                                                 latency_ms=0.3))
    deploy_model(store, m, "m", jax.random.key(3))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32)}
    return store, m, batch


def _engine(store, m, batch, cache):
    from repro.core import ColdStartEngine
    eng = ColdStartEngine(m, "m", store, strategy="cicada",
                          chunk_bytes=1 << 15, cache=cache)
    eng.warmup(batch)
    return eng


def test_second_cold_start_zero_reads_and_zero_retrieval(deployed):
    """Acceptance: with a shared WeightCache and sufficient budget, the
    second cold start of the same model performs zero WeightStore
    read_unit calls, and its trace records ~zero retrieval time."""
    store, m, batch = deployed
    cache = WeightCache(None)
    n_units = len(m.unit_names())

    store.reads = 0
    r1 = _engine(store, m, batch, cache).load(batch)
    assert store.reads == n_units

    r2 = _engine(store, m, batch, cache).load(batch)
    assert store.reads == n_units          # zero additional reads
    R = r2.trace.events_for("R")
    assert set(R) == set(m.unit_names())
    assert all(e.meta and e.meta.get("cached") for e in R.values())
    # ~zero retrieval: cumulative R work is dwarfed by the cold read
    r1_R = sum(e.duration for e in r1.trace.events_for("R").values())
    assert sum(e.duration for e in R.values()) < max(0.01, 0.05 * r1_R)
    np.testing.assert_allclose(np.asarray(r2.logits, np.float32),
                               np.asarray(r1.logits, np.float32),
                               atol=1e-4, rtol=1e-4)
    # pins were all checked in after application
    assert cache.stats().pinned == 0


def test_concurrent_scale_out_single_flights_reads(deployed):
    """Two simultaneous cold starts of one model: exactly one store
    read per unit node-wide (the second loader waits on the shared CV
    instead of duplicating I/O), identical logits from both."""
    store, m, batch = deployed
    cache = WeightCache(None)
    n_units = len(m.unit_names())
    engines = [_engine(store, m, batch, cache) for _ in range(2)]
    store.reads = 0
    out = [None, None]

    def go(i):
        out[i] = engines[i].load(batch)

    ts = [threading.Thread(target=go, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(60.0)
    assert all(o is not None for o in out)
    assert store.reads == n_units          # exactly one read per unit
    st = cache.stats()
    assert st.misses == n_units
    assert st.hits + st.misses == 2 * n_units
    np.testing.assert_allclose(np.asarray(out[0].logits, np.float32),
                               np.asarray(out[1].logits, np.float32),
                               atol=1e-4, rtol=1e-4)


def test_pool_scale_out_shares_platform_cache(deployed):
    """InstancePool wiring: instances provisioned by the pool inherit
    the shared cache, so a scale-out cold start is served without
    re-reading the store."""
    store, m, batch = deployed
    from repro.serving.pool import InstancePool

    cache = WeightCache(None)
    n_units = len(m.unit_names())
    pool = InstancePool("m", lambda: (m, batch), store, strategy="cicada",
                        max_instances=2, chunk_bytes=1 << 15, cache=cache)
    i1 = pool.acquire()
    i2 = pool.acquire()            # scale-out: second container
    store.reads = 0
    i1.invoke(batch)               # cold: reads every unit
    assert store.reads == n_units
    i2.invoke(batch)               # cold, but cache-warm: zero reads
    assert store.reads == n_units
    pool.release(i1, logical_now=0.0, cold=True)
    pool.release(i2, logical_now=0.0, cold=True)
    assert pool.stats().cold_starts == 2


def test_failed_load_does_not_poison_shared_cache(deployed):
    """A cold start whose store read raises must leave the shared
    cache healthy: no wedged loading slots (a later begin() is
    promoted to leader instead of blocking), no leaked pins, and the
    in-flight-load eviction protection lapses."""
    store, m, batch = deployed
    from repro.core import ColdStartEngine

    cache = WeightCache(None)
    bad_unit = m.unit_names()[2]
    orig = type(store).read_unit

    def failing_read(self, model_name, unit, **kw):
        if unit == bad_unit:
            raise IOError("injected read failure")
        return orig(self, model_name, unit, **kw)

    type(store).read_unit = failing_read
    try:
        eng = ColdStartEngine(m, "m", store, strategy="cicada",
                              chunk_bytes=1 << 15, cache=cache)
        with pytest.raises(IOError, match="injected"):
            eng.load(batch)
    finally:
        type(store).read_unit = orig
    assert cache.stats().pinned == 0       # shutdown swept the pins
    # the failed unit's slot was aborted: a fresh begin() leads, fast
    done = {}

    def probe():
        done["status"], _ = cache.begin("m", bad_unit)

    t = threading.Thread(target=probe)
    t.start()
    t.join(5.0)
    assert not t.is_alive(), "begin() wedged on a dead leader"
    assert done["status"] == LOAD
    cache.abort("m", bad_unit)
    # shutdown released the in-flight registration: eviction protection
    # for this model's units has lapsed
    assert cache._inflight == {}


def test_cache_less_engine_unchanged(deployed):
    """No cache (seed behaviour): every cold start re-reads."""
    store, m, batch = deployed
    n_units = len(m.unit_names())
    eng = _engine(store, m, batch, None)
    store.reads = 0
    eng.load(batch)
    eng2 = _engine(store, m, batch, None)
    eng2.load(batch)
    assert store.reads == 2 * n_units
