"""Router / InstancePool / eviction-policy behaviour (the concurrent
serving API): no duplicate pipeline loads, scale-out, inference-first
priority under saturation, admission control, keep-alive edge cases."""
import threading
import time

import pytest

from repro.serving.api import AdmissionError, Request, RequestClass
from repro.serving.policy import (KeepAliveTTL, NeverEvict, make_policy)
from repro.serving.pool import InstancePool
from repro.serving.router import Router


class FakeInstance:
    """FunctionInstance.invoke contract without jax/models."""

    def __init__(self, load_s=0.05, infer_s=0.005):
        self.params = None
        self.loads = 0
        self.load_s = load_s
        self.infer_s = infer_s

    @property
    def live(self):
        return self.params is not None

    def evict(self):
        self.params = None

    def invoke(self, batch):
        if not self.live:
            self.loads += 1
            time.sleep(self.load_s)
            self.params = {"w": 1}
            return None, {"cold": True, "load_s": self.load_s,
                          "infer_s": 0.0, "utilization": 0.9}
        time.sleep(self.infer_s)
        return None, {"cold": False, "load_s": 0.0,
                      "infer_s": self.infer_s, "utilization": 1.0}


def fake_pool(name="m", *, max_instances=1, policy=None, load_s=0.05,
              registry=None):
    insts = registry if registry is not None else []

    def factory():
        inst = FakeInstance(load_s=load_s)
        insts.append(inst)
        return inst

    return InstancePool(name, builder=None, policy=policy,
                        max_instances=max_instances,
                        instance_factory=factory)


def _req(i, model="m", cls=None, t=0.0):
    return Request(req_id=i, model=model, batch={}, t_logical=t, cls=cls)


# ---------------------------------------------------------------------------
# concurrent cold starts
# ---------------------------------------------------------------------------

def test_concurrent_cold_single_instance_one_pipeline():
    """Four concurrent invocations of a cold model with max_instances=1:
    exactly one pipeline load runs; followers are served warm."""
    insts = []
    pool = fake_pool(max_instances=1, load_s=0.1, registry=insts)
    with Router({"m": pool}, workers=4) as router:
        futs = [router.submit(_req(i)) for i in range(4)]
        responses = [f.result(timeout=10) for f in futs]
    assert sum(i.loads for i in insts) == 1
    assert len(insts) == 1
    assert sum(r.cold for r in responses) == 1
    assert sum(not r.cold for r in responses) == 3


def test_concurrent_cold_scales_out_no_duplicate_loads():
    """With max_instances=4, concurrent cold invocations scale out onto
    fresh instances — each container loads at most once."""
    insts = []
    pool = fake_pool(max_instances=4, load_s=0.2, registry=insts)
    with Router({"m": pool}, workers=4) as router:
        futs = [router.submit(_req(i)) for i in range(4)]
        responses = [f.result(timeout=10) for f in futs]
    assert all(i.loads == 1 for i in insts)
    assert len(insts) <= 4
    assert sum(r.cold for r in responses) == len(insts)
    st = pool.stats()
    assert st.size == len(insts)
    assert st.cold_starts + st.warm_hits == 4


def test_in_flight_concurrency_reaches_worker_count():
    insts = []
    pool = fake_pool(max_instances=4, load_s=0.3, registry=insts)
    with Router({"m": pool}, workers=4) as router:
        futs = [router.submit(_req(i)) for i in range(6)]
        for f in futs:
            f.result(timeout=15)
    assert router.stats.max_in_flight >= 4


# ---------------------------------------------------------------------------
# priority dispatch + admission control
# ---------------------------------------------------------------------------

def test_inference_first_ordering_under_saturated_router():
    """One worker, a long-running blocker in service, three queued
    requests with explicit classes: dispatch order must be
    INFERENCE < COLDSTART < BACKGROUND regardless of submit order."""
    pool = fake_pool(max_instances=1, load_s=0.4)
    done = []
    with Router({"m": pool}, workers=1) as router:
        blocker = router.submit(_req(0))
        _wait_dispatched(pool)            # worker is now inside the load
        futs = []
        for rid, cls in [(1, RequestClass.BACKGROUND),
                         (2, RequestClass.COLDSTART),
                         (3, RequestClass.INFERENCE)]:
            f = router.submit(_req(rid, cls=cls))
            f.add_done_callback(
                lambda fut: done.append(fut.result().req_id))
            futs.append(f)
        blocker.result(timeout=10)
        for f in futs:
            f.result(timeout=10)
    assert done == [3, 2, 1]


def test_default_classification_inference_when_warm():
    pool = fake_pool(max_instances=1)
    with Router({"m": pool}, workers=1) as router:
        r0 = router.submit(_req(0)).result(timeout=10)
        assert r0.cls == RequestClass.COLDSTART       # nothing live yet
        r1 = router.submit(_req(1)).result(timeout=10)
        assert r1.cls == RequestClass.INFERENCE       # warm-servable


def _wait_dispatched(pool, n=1, timeout=5.0):
    deadline = time.monotonic() + timeout
    while pool.stats().busy < n:
        assert time.monotonic() < deadline, "dispatch never happened"
        time.sleep(0.005)


def test_admission_control_rejects_when_queue_full():
    pool = fake_pool(max_instances=1, load_s=0.3)
    with Router({"m": pool}, workers=1, max_pending=1) as router:
        blocker = router.submit(_req(0))
        _wait_dispatched(pool)            # blocker dispatched, queue empty
        ok = router.submit(_req(1))       # fills the one pending slot
        with pytest.raises(AdmissionError):
            router.submit(_req(2))
        assert router.stats.rejected == 1
        blocker.result(timeout=10)
        ok.result(timeout=10)


def test_unknown_model_rejected():
    with Router({"m": fake_pool()}, workers=1) as router:
        with pytest.raises(KeyError):
            router.submit(_req(0, model="nope"))


# ---------------------------------------------------------------------------
# instance pool + eviction policies
# ---------------------------------------------------------------------------

def test_acquire_timeout_when_saturated():
    pool = fake_pool(max_instances=1)
    inst = pool.acquire()
    with pytest.raises(TimeoutError):
        pool.acquire(timeout=0.01)
    pool.release(inst, logical_now=0.0)
    assert pool.acquire(timeout=0.1) is inst


def test_ttl_zero_evicts_as_soon_as_clock_advances():
    pool = fake_pool(policy=KeepAliveTTL(0.0))
    inst = pool.acquire()
    inst.invoke({})
    pool.release(inst, logical_now=0.0, cold=True)
    assert pool.sweep(0.0) == 0           # no idle time elapsed yet
    assert inst.live
    assert pool.sweep(1e-9) == 1          # any positive idleness evicts
    assert not inst.live
    assert pool.stats().evictions == 1


def test_never_evict_survives_arbitrary_idle():
    pool = fake_pool(policy=NeverEvict())
    inst = pool.acquire()
    inst.invoke({})
    pool.release(inst, logical_now=0.0, cold=True)
    assert pool.sweep(1e12) == 0
    assert inst.live


def test_sweep_never_touches_busy_instances():
    pool = fake_pool(policy=KeepAliveTTL(0.0))
    inst = pool.acquire()
    inst.invoke({})
    pool.release(inst, logical_now=0.0, cold=True)
    inst2 = pool.acquire()                # same instance, busy again
    assert inst2 is inst
    assert pool.sweep(100.0) == 0         # busy -> not offered to policy
    assert inst.live
    pool.release(inst, logical_now=100.0, cold=False)
    assert pool.sweep(200.0) == 1


def test_make_policy_shorthand():
    assert isinstance(make_policy(None), NeverEvict)
    assert isinstance(make_policy(float("inf")), NeverEvict)
    p = make_policy(60.0)
    assert isinstance(p, KeepAliveTTL)
    assert not p.should_evict(60.0)       # seed semantics: strictly >
    assert p.should_evict(60.0 + 1e-9)
    with pytest.raises(ValueError):
        KeepAliveTTL(-1.0)


def test_warm_idle_preferred_over_cold_scale_out():
    """A live idle instance is reused before provisioning a new one."""
    insts = []
    pool = fake_pool(max_instances=4, registry=insts)
    inst = pool.acquire()
    inst.invoke({})
    pool.release(inst, logical_now=0.0, cold=True)
    again = pool.acquire()
    assert again is inst
    assert len(insts) == 1


# ---------------------------------------------------------------------------
# run_trace on the Router (platform-level, fake pools for determinism)
# ---------------------------------------------------------------------------

def _fake_platform(policy=None, *, max_instances=1, load_s=0.2,
                   registry=None):
    """ServerlessPlatform with its pools swapped for jax-free fakes —
    exercises run_trace's submission/sweep/clock logic in isolation."""
    from repro.metrics import MetricsRegistry
    from repro.serving.engine import ServerlessPlatform
    platform = ServerlessPlatform.__new__(ServerlessPlatform)
    platform.policy = policy if policy is not None else NeverEvict()
    platform.cache = None
    platform.metrics = MetricsRegistry()
    platform.autoscaler = None
    platform.pools = {"m": fake_pool(max_instances=max_instances,
                                     policy=platform.policy,
                                     load_s=load_s, registry=registry)}
    platform.last_router_stats = None
    return platform


def _trace(ts):
    from repro.serving.trace import Invocation
    return [Invocation(t, "m", i) for i, t in enumerate(ts)]


def test_run_trace_concurrent_four_in_flight():
    registry = []
    platform = _fake_platform(max_instances=4, load_s=0.3,
                              registry=registry)
    out = platform.run_trace(_trace([0.0] * 8), lambda name: {},
                             concurrency=4)
    assert len(out) == 8
    assert [r.req_id for r in out] == list(range(8))
    assert platform.last_router_stats.max_in_flight >= 4
    assert all(r.queue_s >= 0 for r in out)
    assert sum(i.loads for i in registry) == sum(r.cold for r in out)


def test_run_trace_serial_matches_seed_lifecycle():
    platform = _fake_platform(policy=KeepAliveTTL(120.0))
    out = platform.run_trace(_trace([0.0, 1.0, 300.0]), lambda name: {})
    assert [r.cold for r in out] == [True, False, True]


def test_run_trace_ttl_zero_every_request_cold():
    platform = _fake_platform(policy=KeepAliveTTL(0.0))
    out = platform.run_trace(_trace([0.0, 1.0, 2.0]), lambda name: {})
    assert [r.cold for r in out] == [True, True, True]


def test_run_trace_never_evict_stays_warm():
    platform = _fake_platform(policy=NeverEvict())
    out = platform.run_trace(_trace([0.0, 1e6, 2e6]), lambda name: {})
    assert [r.cold for r in out] == [True, False, False]


def test_latency_excludes_provisioning():
    """Instance provisioning (builder + warmup compile) is queue time,
    not service latency — latency_s measures the invocation only."""
    def slow_factory():
        time.sleep(0.3)                   # deploy-time warmup
        return FakeInstance(load_s=0.05)

    pool = InstancePool("m", builder=None, instance_factory=slow_factory)
    with Router({"m": pool}, workers=1) as router:
        r = router.submit(_req(0)).result(timeout=10)
    assert r.cold
    assert r.latency_s < 0.2              # ~load_s, not factory's 0.3 s
    assert r.queue_s >= 0.3               # provisioning accounted here


def test_concurrent_replay_still_honours_keepalive():
    """Even when as-fast-as-possible replay runs far ahead of the
    logical clock, an idle instance whose TTL expired before the
    requester's arrival is evicted at acquire time (cold again)."""
    platform = _fake_platform(policy=KeepAliveTTL(45.0), load_s=0.05)
    out = platform.run_trace(_trace([0.0, 100.0]), lambda name: {},
                             concurrency=2)
    assert [r.cold for r in out] == [True, True]


def test_saturated_cold_pool_does_not_starve_warm_inference():
    """Workers requeue on a saturated pool instead of blocking, so a
    queued warm request on another model is served while a cold start
    is still in flight."""
    pool_a = fake_pool("a", max_instances=1, load_s=0.8)
    pool_b = fake_pool("b", max_instances=1, load_s=0.01)
    b_inst = pool_b.acquire()
    b_inst.invoke({})                     # warm b up front
    pool_b.release(b_inst, logical_now=0.0, cold=True)
    with Router({"a": pool_a, "b": pool_b}, workers=2) as router:
        a1 = router.submit(_req(0, model="a"))
        a2 = router.submit(_req(1, model="a"))
        _wait_dispatched(pool_a)
        b1 = router.submit(_req(2, model="b"))
        rb = b1.result(timeout=10)
        ra2 = a2.result(timeout=10)
    assert not rb.cold
    assert rb.t_done < ra2.t_done         # b served during a's cold work
    assert rb.queue_s < 0.6


def test_response_has_seed_fields_plus_queueing():
    platform = _fake_platform()
    (r,) = platform.run_trace(_trace([0.0]), lambda name: {})
    for field in ("req_id", "model", "cold", "t_arrival", "t_done",
                  "load_s", "infer_s", "utilization", "queue_s"):
        assert hasattr(r, field)
    assert r.latency_s > 0
