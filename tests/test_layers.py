"""Property-based tests for the primitive layers (hypothesis)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, strategies as st

from repro.models import layers

dims = st.integers(min_value=1, max_value=8)


@given(b=dims, s=dims, d=st.sampled_from([8, 16, 64]),
       seed=st.integers(0, 2 ** 16))
def test_rmsnorm_unit_rms(b, s, d, seed):
    """With zero scale offset, the output has (close to) unit RMS."""
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((b, s, d)),
                    jnp.float32) * 7.0 + 1.0
    y = layers.rmsnorm(x, jnp.zeros((d,)))
    rms = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


@given(seed=st.integers(0, 2 ** 16))
def test_rmsnorm_scale_invariance(seed):
    """rmsnorm(c*x) == rmsnorm(x) for any positive scalar c."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((2, 3, 16)), jnp.float32)
    c = float(r.uniform(0.1, 100.0))
    sc = jnp.asarray(r.standard_normal(16), jnp.float32)
    y1 = layers.rmsnorm(x, sc)
    y2 = layers.rmsnorm(c * x, sc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


@given(seed=st.integers(0, 2 ** 16))
def test_layernorm_standardizes(seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((2, 5, 32)) * 3 + 5, jnp.float32)
    y = layers.layernorm(x, jnp.ones((32,)), jnp.zeros((32,)))
    yn = np.asarray(y)
    np.testing.assert_allclose(yn.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(yn.std(-1), 1.0, atol=1e-2)


@given(seed=st.integers(0, 2 ** 16),
       dh=st.sampled_from([8, 16, 64]))
def test_rope_preserves_norm(seed, dh):
    """Rotations preserve vector norms."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((1, 6, 2, dh)), jnp.float32)
    pos = jnp.asarray(r.integers(0, 1000, (1, 6)), jnp.int32)
    y = layers.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)


@given(seed=st.integers(0, 2 ** 16))
def test_rope_relative_positions(seed):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    r = np.random.default_rng(seed)
    dh = 16
    q = jnp.asarray(r.standard_normal((1, 1, 1, dh)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 1, 1, dh)), jnp.float32)

    def dot_at(i, j):
        qi = layers.apply_rope(q, jnp.asarray([[i]]), 10000.0)
        kj = layers.apply_rope(k, jnp.asarray([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))

    np.testing.assert_allclose(dot_at(5, 3), dot_at(105, 103), rtol=1e-3,
                               atol=1e-4)


def test_rope_zero_position_is_identity():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 1, 2, 32)),
                    jnp.float32)
    y = layers.apply_rope(x, jnp.zeros((1, 1), jnp.int32), 10000.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


@given(w=st.integers(1, 4), s=st.integers(1, 12),
       seed=st.integers(0, 2 ** 16))
def test_causal_conv_streaming_equivalence(w, s, seed):
    """Processing token-by-token with carried state == full-sequence."""
    r = np.random.default_rng(seed)
    B, C = 2, 6
    x = jnp.asarray(r.standard_normal((B, s, C)), jnp.float32)
    kern = jnp.asarray(r.standard_normal((w, C)), jnp.float32)
    y_full, _ = layers.causal_conv1d(x, kern)
    state = None
    ys = []
    for t in range(s):
        y_t, state = layers.causal_conv1d(x[:, t:t + 1], kern, state)
        ys.append(y_t)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               atol=1e-5, rtol=1e-5)


def test_causal_conv_causality():
    """Output at t must not depend on inputs after t."""
    r = np.random.default_rng(3)
    x = jnp.asarray(r.standard_normal((1, 10, 4)), jnp.float32)
    kern = jnp.asarray(r.standard_normal((4, 4)), jnp.float32)
    y1, _ = layers.causal_conv1d(x, kern)
    x2 = x.at[:, 7:].set(99.0)
    y2, _ = layers.causal_conv1d(x2, kern)
    np.testing.assert_array_equal(np.asarray(y1[:, :7]),
                                  np.asarray(y2[:, :7]))
