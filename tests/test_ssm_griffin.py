"""Mamba-2 SSD block and Griffin RG-LRU block: full-sequence vs
step-by-step decode equivalence, state carrying, causality."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import griffin, ssm
from repro.models.api import get_config


def _ssm_cfg():
    return dataclasses.replace(get_config("mamba2-780m", smoke=True),
                               compute_dtype=jnp.float32)


def _grf_cfg():
    return dataclasses.replace(get_config("recurrentgemma-2b", smoke=True),
                               compute_dtype=jnp.float32)


def test_ssd_block_decode_equivalence():
    cfg = _ssm_cfg()
    p = ssm.ssd_params(cfg, jax.random.key(0))
    r = np.random.default_rng(0)
    B, S = 2, 12
    x = jnp.asarray(r.standard_normal((B, S, cfg.d_model)), jnp.float32)
    y_full = ssm.ssd_block(cfg, p, x)
    conv, state = ssm.init_states(cfg, B)
    ys = []
    for t in range(S):
        y_t, conv, state = ssm.ssd_decode(cfg, p, x[:, t:t + 1], conv, state)
        ys.append(y_t)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               atol=1e-4, rtol=1e-3)


def test_ssd_block_causality():
    cfg = _ssm_cfg()
    p = ssm.ssd_params(cfg, jax.random.key(1))
    r = np.random.default_rng(1)
    x = jnp.asarray(r.standard_normal((1, 10, cfg.d_model)), jnp.float32)
    y1 = ssm.ssd_block(cfg, p, x)
    x2 = x.at[:, 6:].set(3.0)
    y2 = ssm.ssd_block(cfg, p, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :6]), np.asarray(y2[:, :6]),
                               atol=1e-5)


def test_ssd_state_continuation():
    """Processing [first half] then [second half with carried state] ==
    processing the full sequence."""
    cfg = _ssm_cfg()
    p = ssm.ssd_params(cfg, jax.random.key(2))
    r = np.random.default_rng(2)
    B, S = 1, 16
    x = jnp.asarray(r.standard_normal((B, S, cfg.d_model)), jnp.float32)
    y_full, (conv_f, h_f) = ssm.ssd_block(cfg, p, x, return_state=True)
    y1, (conv1, h1) = ssm.ssd_block(cfg, p, x[:, :8], return_state=True)
    y2, _ = ssm.ssd_block(cfg, p, x[:, 8:], conv_state=conv1, ssm_state=h1,
                          return_state=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)


def test_rglru_block_decode_equivalence():
    cfg = _grf_cfg()
    p = griffin.rglru_params(cfg, jax.random.key(0))
    r = np.random.default_rng(0)
    B, S = 2, 10
    x = jnp.asarray(r.standard_normal((B, S, cfg.d_model)), jnp.float32)
    y_full = griffin.rglru_block(cfg, p, x)
    conv, h = griffin.init_states(cfg, B)
    ys = []
    for t in range(S):
        y_t, conv, h = griffin.rglru_decode(cfg, p, x[:, t:t + 1], conv, h)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)


def test_rglru_gates_bounded():
    """Recurrence factor a must lie in (0, 1) — stability."""
    cfg = _grf_cfg()
    p = griffin.rglru_params(cfg, jax.random.key(3))
    r = np.random.default_rng(3)
    px = jnp.asarray(r.standard_normal((2, 20, cfg.rglru_width)) * 5,
                     jnp.float32)
    a, b = griffin._gates(p, px)
    an = np.asarray(a)
    assert (an > 0).all() and (an < 1).all()
    # input scale sqrt(1 - a^2) also bounded
    assert np.isfinite(np.asarray(b)).all()


def test_rglru_block_causality():
    cfg = _grf_cfg()
    p = griffin.rglru_params(cfg, jax.random.key(4))
    r = np.random.default_rng(4)
    x = jnp.asarray(r.standard_normal((1, 12, cfg.d_model)), jnp.float32)
    y1 = griffin.rglru_block(cfg, p, x)
    x2 = x.at[:, 8:].set(-2.0)
    y2 = griffin.rglru_block(cfg, p, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :8]), np.asarray(y2[:, :8]),
                               atol=1e-5)
